"""API-conformance suite for the public serving API (DESIGN.md §10).

The same generate / stream / abort / stop-token scenarios run against every
execution substrate `serving.build` can produce — the roofline simulator,
the exact engine, and a timing-only trace replay, single- and
multi-replica — through the one `LLMServer` surface.  Where determinism
holds (greedy engines, placeholder-token sims, strict replay) outputs are
asserted identical.

Also here: the abort-semantics regression tests (mid-queue, mid-decode,
in-flight, stolen-waiting, and mid-KV-migration — slots and pages must free
in every case), the spec JSON round trip (incl. per-request priority/SLO
and per-replica `sim_overrides`), and the service-rate EWMA surface.
"""

import asyncio

import numpy as np
import pytest

from repro.serving import (
    FINISH_ABORT,
    FINISH_LENGTH,
    FINISH_STOP,
    ClusterSpec,
    EngineSpec,
    RebalancePolicy,
    ReplicaCapacity,
    SamplingParams,
    ServeSpec,
    SimSpec,
    TraceSpec,
    build,
)

SIM_ENGINE = EngineSpec(arch="qwen2.5-14b")
SIM = SimSpec(pp=2, pages=256, page_size=8)
TOY_ENGINE = EngineSpec(
    arch="qwen1.5-0.5b",
    throttle=dict(num_iters_T=2, max_prefill_tokens=16,
                  min_prefill_tokens=4),
    dims=dict(C=16, pages=256, Bp=32, Bd=32))

BACKENDS = ["sim", "sim2", "engine", "engine2", "replay"]


def make_spec(kind, record=None):
    trace = TraceSpec(record=record) if record else None
    if kind == "sim":
        return ServeSpec(backend="sim", engine=SIM_ENGINE, sim=SIM,
                         trace=trace)
    if kind == "sim2":
        return ServeSpec(backend="sim", engine=SIM_ENGINE, sim=SIM,
                         cluster=ClusterSpec(replicas=2), trace=trace)
    if kind == "engine":
        return ServeSpec(engine=TOY_ENGINE, trace=trace)
    if kind == "engine2":
        return ServeSpec(engine=TOY_ENGINE,
                         cluster=ClusterSpec(replicas=2), trace=trace)
    raise ValueError(kind)


@pytest.fixture(scope="module")
def replay_source(tmp_path_factory):
    """A recorded sim run: the substrate of the timing-only replay server."""
    path = str(tmp_path_factory.mktemp("traces") / "source.trace.jsonl")
    srv = build(make_spec("sim", record=path))
    for i in range(4):
        srv.submit([i + 1] * 12, SamplingParams(max_new_tokens=6))
    srv.drain()
    srv.close()
    return path


@pytest.fixture(scope="module", params=BACKENDS)
def server(request, replay_source):
    if request.param == "replay":
        return build(ServeSpec(
            backend="trace",
            trace=TraceSpec(replay=replay_source, timing_only=True)))
    return build(make_spec(request.param))


def prompt(server, n, seed=0):
    rng = np.random.default_rng(seed)
    vocab = server.cfg.vocab_size if server.cfg is not None else 1000
    return list(rng.integers(0, vocab, n))


# ---------------------------------------------------------------------------
# the shared scenarios
# ---------------------------------------------------------------------------

class TestConformance:
    def test_generate_runs_to_length(self, server):
        out = server.generate(prompt(server, 11),
                              SamplingParams(max_new_tokens=4))
        assert out.finish_reason == FINISH_LENGTH
        assert len(out.token_ids) == 4
        assert out.metrics.ttft() is not None and out.metrics.ttft() >= 0
        assert out.metrics.e2el() >= out.metrics.ttft()

    def test_generate_is_deterministic(self, server):
        p = prompt(server, 9, seed=1)
        a = server.generate(p, SamplingParams(max_new_tokens=4))
        b = server.generate(p, SamplingParams(max_new_tokens=4))
        assert a.token_ids == b.token_ids        # greedy / placeholder / replayed

    def test_stop_token_truncates(self, server):
        p = prompt(server, 8, seed=2)
        ref = server.generate(p, SamplingParams(max_new_tokens=6))
        stop = ref.token_ids[1]
        cut = ref.token_ids.index(stop)
        out = server.generate(p, SamplingParams(max_new_tokens=6,
                                                stop_token_ids=(stop,)))
        assert out.finish_reason == FINISH_STOP
        assert out.token_ids == ref.token_ids[:cut + 1]

    def test_stream_deltas_are_contiguous_and_terminated(self, server):
        async def run():
            deltas = []
            async for d in server.generate_stream(
                    prompt(server, 7, seed=3),
                    SamplingParams(max_new_tokens=3)):
                deltas.append(d)
            return deltas

        deltas = asyncio.run(run())
        tokens = [d for d in deltas if d.token is not None]
        assert [d.index for d in tokens] == [1, 2, 3]
        assert deltas[-1].finish_reason == FINISH_LENGTH
        assert all(d.finish_reason is None for d in deltas[:-1])

    def test_abort_mid_queue(self, server):
        long_rid = server.submit(prompt(server, 10, seed=4),
                                 SamplingParams(max_new_tokens=6))
        rid = server.submit(prompt(server, 10, seed=5),
                            SamplingParams(max_new_tokens=6))
        assert server.abort(rid)                 # still waiting: immediate
        out = server.get(rid)
        assert out.finish_reason == FINISH_ABORT
        assert out.token_ids == []
        server.drain()
        assert server.get(long_rid).finish_reason == FINISH_LENGTH
        self._assert_no_leak(server, rid)

    def test_abort_mid_decode(self, server):
        rid = server.submit(prompt(server, 10, seed=6),
                            SamplingParams(max_new_tokens=64))
        req = server._requests[rid]
        for _ in range(200):
            if req.num_output_tokens >= 1:
                break
            server.step()
        assert req.num_output_tokens >= 1, "request never started decoding"
        assert server.abort(rid)
        server.drain()
        out = server.get(rid)
        assert out.finish_reason == FINISH_ABORT
        assert len(out.token_ids) < 64
        self._assert_no_leak(server, rid)
        # the aborted stream surfaces the abort, not a trailing token
        assert server.abort(rid) is False        # already finished

    def test_stats_expose_service_rate(self, server):
        server.generate(prompt(server, 8, seed=7),
                        SamplingParams(max_new_tokens=4))
        stats = server.stats()
        assert stats.tokens_retired > 0
        assert any(r.service_rate is not None and r.service_rate > 0
                   for r in stats.replicas)
        for r in stats.replicas:
            assert 0.0 <= r.kv_free_rate <= 1.0
        if server.router is not None:
            assert stats.routed_counts is not None
            assert sum(stats.routed_counts) > 0

    @staticmethod
    def _assert_no_leak(server, rid):
        for replica in server.replicas:
            sched = replica.scheduler
            assert not sched.kv.has_request(rid)
            assert all(r.request_id != rid for r in sched.waiting)
            assert all(r.request_id != rid for r in sched.running_decode)
            assert all(r.request_id != rid for r in sched.running_prefill)
            slots = getattr(replica, "slots", None)
            if slots is not None:
                assert rid not in slots.owner


# ---------------------------------------------------------------------------
# determinism across substrates: record -> strict replay is bit-identical
# ---------------------------------------------------------------------------

def _scenario(server):
    """The canonical mixed scenario: a normal request, an aborted one, a
    stop-token one.  Returns {rid: (tokens, finish_reason)}."""
    r1 = server.submit([1] * 16, SamplingParams(max_new_tokens=6))
    r2 = server.submit([2] * 20, SamplingParams(max_new_tokens=40))
    server.step(); server.step(); server.step()
    assert server.abort(r2)
    r3 = server.submit([3] * 10, SamplingParams(max_new_tokens=4,
                                                stop_token_ids=(0,)))
    server.drain()
    return {o.request_id: (tuple(o.token_ids), o.finish_reason)
            for o in server.outputs([r1, r2, r3])}


def test_strict_replay_reproduces_recorded_scenario(tmp_path):
    path = str(tmp_path / "scenario.trace.jsonl")
    rec = build(make_spec("sim", record=path))
    want = _scenario(rec)
    rec.close()
    assert sorted(r for _, r in want.values()) == ["abort", "length", "stop"]

    replay = build(ServeSpec(backend="trace", trace=TraceSpec(replay=path)))
    outs = replay.replay()
    got = {o.request_id: (tuple(o.token_ids), o.finish_reason) for o in outs}
    assert got == want

    # interactive calls are refused with a pointer at the right spec
    with pytest.raises(RuntimeError, match="timing_only"):
        replay.generate([1, 2, 3])


def test_sim_rebuild_is_deterministic():
    a = build(make_spec("sim"))
    b = build(make_spec("sim"))
    wa = _scenario(a)
    wb = _scenario(b)
    assert set(wa.values()) == set(wb.values())  # fresh rid namespaces


# ---------------------------------------------------------------------------
# abort through the router: steal queues and in-transit migrations
# ---------------------------------------------------------------------------

@pytest.fixture()
def sim_cluster_server():
    return build(ServeSpec(backend="sim", engine=SIM_ENGINE,
                           sim=SimSpec(pp=2, pages=128, page_size=8),
                           cluster=ClusterSpec(replicas=2)))


def test_abort_in_transit_migration_frees_everything(sim_cluster_server):
    """Regression: aborting a request whose KV payload is between replicas
    must drop the queued delivery — neither replica may end up holding its
    pages, and the migration bookkeeping must clear."""
    srv = sim_cluster_server
    cluster = srv.engine
    router = cluster.router
    rid = srv.submit([1] * 24, SamplingParams(max_new_tokens=64))
    for _ in range(8):
        srv.step()
    src = next(i for i, s in enumerate(cluster.sims)
               if s.scheduler.kv.has_request(rid))
    assert router.migrate_request(rid, src, 1 - src)
    assert router.has_in_transit                 # modeled transfer latency
    assert srv.abort(rid)
    assert not router.has_in_transit
    assert srv.get(rid).finish_reason == FINISH_ABORT
    srv.drain()
    for s in cluster.sims:
        assert not s.scheduler.kv.has_request(rid)
        assert s.scheduler.kv.kv_free_rate == 1.0
    assert rid not in router._migrations_of
    assert rid in {r.request_id for r in router.finished}


def test_abort_stolen_waiting_request(sim_cluster_server):
    """Regression: a waiting request drained off one replica and adopted by
    another (the control plane's steal path) must abort cleanly on the
    destination."""
    srv = sim_cluster_server
    cluster = srv.engine
    router = cluster.router
    rid = srv.submit([2] * 16, SamplingParams(max_new_tokens=8))
    src = next(i for i, s in enumerate(cluster.sims)
               if any(r.request_id == rid for r in s.scheduler.waiting))
    assert router.migrate_request(rid, src, 1 - src)   # waiting => steal
    dst = cluster.sims[1 - src].scheduler
    assert any(r.request_id == rid for r in dst.waiting)
    assert srv.abort(rid)
    assert srv.get(rid).finish_reason == FINISH_ABORT
    assert not any(r.request_id == rid for r in dst.waiting)
    assert dst.kv.kv_free_rate == 1.0


def test_cluster_drain_reports_each_finish_exactly_once():
    """Regression: finishes land in per-replica lists, so "what finished
    since" must be tracked per source — slicing the concatenated list
    dropped replica-0 finishes and duplicated replica-1's tail."""
    srv = build(ServeSpec(backend="sim", engine=SIM_ENGINE, sim=SIM,
                          cluster=ClusterSpec(replicas=2)))
    rids = [srv.submit([i + 1] * 12, SamplingParams(max_new_tokens=4))
            for i in range(6)]
    seen = [o.request_id for o in srv.drain()]
    assert sorted(seen) == sorted(rids), seen
    assert min(srv.stats().routed_counts) >= 1, "needs both replicas used"


def test_fault_finalizes_pending_abort():
    """Regression: a worker fault hitting a micro-batch whose request has a
    pending abort must finalize the abort (KV freed, surfaced through the
    finished lists with a sane finish time), not requeue a recompute."""
    srv = build(ServeSpec(backend="sim", engine=SIM_ENGINE,
                          sim=SimSpec(pp=2, pages=256, page_size=8)))
    sim = srv.engine
    rid = srv.submit([1] * 12, SamplingParams(max_new_tokens=8))
    srv.step()                        # micro-batch in flight (depth 2)
    req = srv._requests[rid]
    assert srv.abort(rid) and not req.is_finished     # deferred
    sim.inject_failure(sim.backend.time, downtime=0.5)
    srv.drain()
    out = srv.get(rid)
    assert out.finish_reason == FINISH_ABORT
    assert out.metrics.finish_time >= out.metrics.arrival_time
    assert rid in {r.request_id for r in sim.metrics.finished}
    assert sim.scheduler.kv.kv_free_rate == 1.0
    assert not sim.scheduler.has_work


def test_abort_in_flight_finalizes_at_retire():
    """Scheduler-level: an abort landing while the request is inside an
    in-flight micro-batch defers to complete(), which discards the sampled
    token, frees the KV, and reports the request finished."""
    from repro.core import (PagedKVManager, PipelineScheduler, Request,
                            ThrottleConfig)
    sched = PipelineScheduler(ThrottleConfig(pipeline_depth=2),
                              PagedKVManager(64, 8))
    req = Request("r1", [1] * 12, SamplingParams(max_new_tokens=8))
    sched.add_request(req)
    batch = sched.schedule(0.0)
    assert [s.request.request_id for s in batch.seqs] == ["r1"]
    got = sched.abort_request("r1", 0.5)
    assert got is req and not req.is_finished    # deferred
    assert sched.kv.has_request("r1")            # still materializing
    finished = sched.complete(batch.batch_id, [7], 1.0)
    assert finished == [req]
    assert req.finish_reason == FINISH_ABORT
    assert req.output_token_ids == []            # sampled token discarded
    assert not sched.kv.has_request("r1")
    assert not sched.has_work
    sched.check_invariants()


def test_preemption_surfaces_stream_events():
    """A preempted-then-recovered request's stream carries the
    event="preempt" delta and tags the first recomputed token."""
    from repro.serving import EVENT_PREEMPT, EVENT_PREEMPT_RESUMED
    srv = build(ServeSpec(backend="sim", engine=SIM_ENGINE,
                          sim=SimSpec(pp=1, pages=8, page_size=4)))

    async def run():
        outs = await asyncio.gather(*[
            _collect(srv.generate_stream([i + 1] * 8,
                                         SamplingParams(max_new_tokens=16)))
            for i in range(2)])
        return outs

    deltas = [d for out in asyncio.run(run()) for d in out]
    assert srv.stats().replicas[0].preemptions >= 1, "needs KV pressure"
    events = [d.event for d in deltas if d.event is not None]
    assert EVENT_PREEMPT in events
    assert EVENT_PREEMPT_RESUMED in events
    # every stream still terminated exactly once
    finals = [d for d in deltas if d.finish_reason is not None]
    assert len(finals) == 2


async def _collect(stream):
    return [d async for d in stream]


# ---------------------------------------------------------------------------
# spec JSON round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    ServeSpec(),
    ServeSpec(backend="sim", sim=SimSpec(pp=8, straggler_stage=2,
                                         straggler_factor=1.5)),
    ServeSpec(backend="trace", trace=TraceSpec(replay="x.jsonl",
                                               timing_only=True)),
    ServeSpec(engine=EngineSpec(arch="qwen2.5-14b", policy="sarathi",
                                throttle={"num_iters_T": 2},
                                dims={"Sd": 16}),
              cluster=ClusterSpec(
                  replicas=3, route="rr",
                  rebalance=RebalancePolicy(interval=0.5, migrate=False),
                  capacities=(1.0, ReplicaCapacity.straggler(4, 2.0),
                              ReplicaCapacity.scaled(1.5))),
              trace=TraceSpec(record="out.jsonl")),
    ServeSpec(backend="sim",
              cluster=ClusterSpec(
                  replicas=3,
                  sim_overrides=(None,
                                 {"straggler_stage": 1,
                                  "straggler_factor": 2.0},
                                 {"pp": 8, "pages": 512}))),
    ServeSpec(engine=EngineSpec(dispatch="async", bucketed=True)),
])
def test_spec_json_round_trip(spec):
    assert ServeSpec.from_json(spec.to_json()) == spec
    assert ServeSpec.from_json(spec.to_json(indent=2)) == spec


def test_spec_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown"):
        ServeSpec.from_json('{"backend": "sim", "typo": 1}')


def test_spec_rejects_unknown_dispatch():
    with pytest.raises(ValueError, match="dispatch"):
        EngineSpec(dispatch="eager")
    with pytest.raises(ValueError, match="dispatch"):
        ServeSpec.from_json('{"engine": {"dispatch": "eager"}}')


def test_spec_validates_shapes():
    with pytest.raises(ValueError):
        ServeSpec(backend="trace")               # replay path required
    with pytest.raises(ValueError):
        ServeSpec(backend="nope")
    with pytest.raises(ValueError):
        ClusterSpec(replicas=0)
    with pytest.raises(ValueError):
        ClusterSpec(replicas=2, capacities=(1.0,))


# ---------------------------------------------------------------------------
# per-request SLO class + per-replica overrides through the public surface
# ---------------------------------------------------------------------------

def test_slo_class_and_priority_round_trip_and_serve():
    """A batch-class prioritized request is a first-class citizen of the
    API: constructible, validated, and served to completion."""
    with pytest.raises(ValueError, match="slo_class"):
        SamplingParams(slo_class="platinum")
    srv = build(make_spec("sim"))
    out = srv.generate([3] * 16, SamplingParams(max_new_tokens=4,
                                                slo_class="batch",
                                                priority=7))
    assert out.finish_reason == FINISH_LENGTH
    assert len(out.token_ids) == 4


def test_sim_overrides_build_heterogeneous_replicas():
    spec = ServeSpec(backend="sim", engine=SIM_ENGINE, sim=SIM,
                     cluster=ClusterSpec(replicas=2, sim_overrides=(
                         None, {"pp": 4, "pages": 64})))
    srv = build(spec)
    assert srv.replicas[0].pp == 2 and srv.replicas[1].pp == 4
    assert srv.replicas[0].sched.kv.num_pages == 256
    assert srv.replicas[1].sched.kv.num_pages == 64
    # declared asymmetry is visible to balanced routing end-to-end
    for _ in range(4):
        srv.generate([1] * 32, SamplingParams(max_new_tokens=2))
    assert sum(srv.stats().routed_counts) == 4
    srv.close()


def test_interactive_beats_equal_arrival_batch_on_ttft():
    """Acceptance regression (ISSUE 5): under a saturated eq. 3 token
    budget, an interactive-class request submitted *after* an equal-arrival
    batch-class twin still reaches its first token sooner — SLO-ordered
    admission, not FCFS, spends the throttled budget."""
    spec = ServeSpec(backend="sim",
                     engine=EngineSpec(arch="qwen2.5-14b",
                                       throttle=dict(max_prefill_tokens=64)),
                     sim=SimSpec(pp=4, pages=1024, page_size=8))
    srv = build(spec)
    for _ in range(10):     # ~960 pending prefill tokens: budget saturated
        srv.submit([1] * 96, SamplingParams(max_new_tokens=8))
    rid_batch = srv.submit([2] * 64, SamplingParams(max_new_tokens=8,
                                                    slo_class="batch"))
    rid_inter = srv.submit([2] * 64, SamplingParams(max_new_tokens=8))
    srv.drain()
    ttft_batch = srv.get(rid_batch).metrics.ttft()
    ttft_inter = srv.get(rid_inter).metrics.ttft()
    assert ttft_inter is not None and ttft_batch is not None
    assert ttft_inter < ttft_batch
    srv.close()


def test_sim_overrides_validation():
    with pytest.raises(ValueError, match="one sim_overrides"):
        ClusterSpec(replicas=2, sim_overrides=({"pp": 4},))
    with pytest.raises(ValueError, match="unknown SimSpec fields"):
        ClusterSpec(replicas=1, sim_overrides=({"nope": 1},))
    with pytest.raises(ValueError, match='backend="sim"'):
        ServeSpec(backend="engine",
                  cluster=ClusterSpec(replicas=2,
                                      sim_overrides=(None, {"pp": 4})))
