"""Live-migration invariants (DESIGN.md §9).

Three layers, matching the migration protocol:

  * host addressing — `PagedKVManager.export_kv`/`import_kv` re-map a
    request's resident tokens onto another pool's slots (property-tested:
    counts match, destination slots are valid/unique, page accounting
    balances on both ends);
  * scheduler state — `drain_request`/`adopt_request` move a request between
    schedulers at its current position (property-tested against random
    workloads: nothing lost, nothing duplicated, progress preserved);
  * whole system — a SimCluster run with the rebalance control plane
    completes every request and its per-replica traces (with `migrate`
    records) strict-replay byte-identically; the engine-level bit-identity
    test (a migrated request's tokens equal the dense reference) lives in
    tests/test_engine_migration.py because it needs jax.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.configs import get_config
from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    Request,
    RequestState,
    SamplingParams,
    ThrottleConfig,
)
from repro.data.workload import SHAREGPT, sample_requests
from repro.runtime.router import (
    RebalancePolicy,
    ReplicaCapacity,
    ReplicaRouter,
    SimCluster,
)
from repro.runtime.simulator import PipelineSimulator, cost_model_for

CFG = get_config("qwen2.5-14b")


def make_sched(pp=3, pages=256, page_size=8):
    th = ThrottleConfig(pipeline_depth=pp, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=page_size)
    return PipelineScheduler(th, kv, max_model_len=pages * page_size)


# ---------------------------------------------------------------------------
# Host-side KV export/import
# ---------------------------------------------------------------------------

class TestKVExportImport:
    def test_slot_remapping_roundtrip(self):
        src = PagedKVManager(num_pages=16, page_size=4)
        dst = PagedKVManager(num_pages=8, page_size=4)
        src.allocate("a", 10)
        export = src.export_kv("a")
        assert export.num_tokens == 10
        assert len(export.slots) == 10
        dst_slots = dst.import_kv(export)
        assert len(dst_slots) == 10
        # position i of the sequence maps source slot i -> dest slot i
        assert dst.num_tokens("a") == 10
        src.free("a")
        src.check_invariants()
        dst.check_invariants()

    def test_import_rejects_duplicate_and_overflow(self):
        src = PagedKVManager(num_pages=16, page_size=4)
        src.allocate("a", 10)
        export = src.export_kv("a")
        tiny = PagedKVManager(num_pages=2, page_size=4)
        with pytest.raises(MemoryError):
            tiny.import_kv(export)
        dst = PagedKVManager(num_pages=8, page_size=4)
        dst.import_kv(export)
        with pytest.raises(ValueError):
            dst.import_kv(export)

    def test_export_unknown_request_raises(self):
        kv = PagedKVManager(num_pages=4, page_size=4)
        with pytest.raises(KeyError):
            kv.export_kv("nope")

    if HAS_HYPOTHESIS:
        @given(tokens=st.integers(1, 200),
               src_page=st.integers(1, 16),
               dst_page=st.integers(1, 16))
        @settings(max_examples=40, deadline=None)
        def test_remap_valid_on_any_geometry(self, tokens, src_page,
                                             dst_page):
            """Page sizes may differ across replicas: the mapping is per
            token, every destination slot unique and in range, and page
            accounting balances on both managers."""
            src = PagedKVManager(num_pages=(tokens // src_page) + 2,
                                 page_size=src_page)
            dst = PagedKVManager(num_pages=(tokens // dst_page) + 2,
                                 page_size=dst_page)
            src.allocate("a", tokens)
            export = src.export_kv("a")
            dst_slots = dst.import_kv(export)
            assert len(dst_slots) == tokens
            assert len(set(dst_slots)) == tokens
            for pg, off in dst_slots:
                assert 0 <= pg < dst.num_pages
                assert 0 <= off < dst.page_size
            src.free("a")
            src.check_invariants()
            dst.check_invariants()
            assert dst.num_tokens("a") == tokens


# ---------------------------------------------------------------------------
# Scheduler drain/adopt
# ---------------------------------------------------------------------------

def _run_ticks(sched, n, clock_start=0.0):
    """Drive a depth-1 toy loop: schedule+complete with dummy tokens."""
    now = clock_start
    for _ in range(n):
        batch = sched.schedule(now)
        toks = [7] * sum(1 for s in batch.seqs if s.produces_token)
        sched.complete(batch.batch_id, toks, now)
        now += 1.0
    return now


class TestDrainAdopt:
    def test_drain_decode_and_adopt_elsewhere(self):
        a, b = make_sched(), make_sched()
        req = Request("x", [1] * 20, SamplingParams(max_new_tokens=50))
        a.add_request(req)
        _run_ticks(a, 4)
        assert req in a.running_decode and req.num_output_tokens > 0
        out_before = list(req.output_token_ids)
        prefilled = req.num_prefilled

        drained = a.drain_request("x")
        assert drained is req
        export = a.kv.export_kv("x")
        a.kv.free("x")
        b.kv.import_kv(export)
        b.adopt_request(drained)

        assert req not in a.running_decode and req in b.running_decode
        assert req.state is RequestState.DECODING
        assert req.num_prefilled == prefilled          # no recompute
        assert req.output_token_ids == out_before
        a.check_invariants()
        b.check_invariants()
        _run_ticks(b, 100)
        assert req.is_finished
        assert req.num_output_tokens == 50

    def test_drain_refuses_in_flight(self):
        a = make_sched()
        req = Request("x", [1] * 8, SamplingParams(max_new_tokens=4))
        a.add_request(req)
        a.schedule(0.0)                  # in flight until complete()
        assert a.drain_request("x") is None

    def test_drain_waiting_has_no_kv(self):
        a, b = make_sched(), make_sched()
        req = Request("x", [1] * 8, SamplingParams(max_new_tokens=4))
        a.add_request(req)
        drained = a.drain_request("x")
        assert drained is req and not a.kv.has_request("x")
        b.adopt_request(drained)
        assert req in b.waiting

    def test_adopt_requires_imported_kv(self):
        a, b = make_sched(), make_sched()
        req = Request("x", [1] * 20, SamplingParams(max_new_tokens=50))
        a.add_request(req)
        _run_ticks(a, 4)
        drained = a.drain_request("x")
        a.kv.free("x")
        with pytest.raises(ValueError):
            b.adopt_request(drained)     # forgot import_kv

    def test_steal_candidates_skip_kv_holders(self):
        a = make_sched()
        r1 = Request("x", [1] * 8, SamplingParams(max_new_tokens=4))
        r2 = Request("y", [1] * 8, SamplingParams(max_new_tokens=4))
        a.add_request(r1)
        a.add_request(r2)
        a.kv.allocate("x", 4)            # e.g. an adopted prefix-cache head
        cands = a.steal_candidates()
        assert r2 in cands and r1 not in cands
        # tail-first: the remainder keeps FCFS order
        assert cands[0] is r2

    if HAS_HYPOTHESIS:
        @given(seed=st.integers(0, 2**16), ticks=st.integers(1, 40),
               n_reqs=st.integers(2, 10))
        @settings(max_examples=25, deadline=None)
        def test_drain_adopt_preserves_state_on_random_workloads(
                self, seed, ticks, n_reqs):
            """Migrate every drainable decode request mid-run: nothing is
            lost or duplicated, progress is bit-preserved, both schedulers'
            page accounting balances, and every request still completes."""
            import numpy as np
            rng = np.random.default_rng(seed)
            a, b = make_sched(), make_sched()
            reqs = []
            for i in range(n_reqs):
                r = Request(f"r{i}", [1] * int(rng.integers(4, 60)),
                            SamplingParams(
                                max_new_tokens=int(rng.integers(1, 30))))
                reqs.append(r)
                a.add_request(r)
            _run_ticks(a, ticks)
            snapshot = {r.request_id: (list(r.output_token_ids),
                                       r.num_prefilled)
                        for r in a.running_decode}
            for rid in list(snapshot):
                drained = a.drain_request(rid)
                if drained is None:
                    continue
                export = a.kv.export_kv(rid)
                a.kv.free(rid)
                b.kv.import_kv(export)
                b.adopt_request(drained)
                out, prefilled = snapshot[rid]
                assert drained.output_token_ids == out
                assert drained.num_prefilled == prefilled
                assert b.kv.num_tokens(rid) == prefilled
            a.check_invariants()
            b.check_invariants()
            ids_a = {r.request_id for g in (a.waiting, a.running_prefill,
                                            a.running_decode) for r in g}
            ids_b = {r.request_id for g in (b.waiting, b.running_prefill,
                                            b.running_decode) for r in g}
            assert not (ids_a & ids_b), "request resident on both replicas"
            _run_ticks(a, 500)
            _run_ticks(b, 500)
            assert all(r.is_finished for r in reqs)


# ---------------------------------------------------------------------------
# Cluster-level: control plane end-to-end + trace round trip
# ---------------------------------------------------------------------------

def _hetero_cluster(rebalance, *, pp=4, pages=2048, trace_dir=None):
    cost = cost_model_for(CFG, pp=pp)
    sims = [
        PipelineSimulator(
            PipelineScheduler(
                ThrottleConfig(pipeline_depth=pp),
                PagedKVManager(num_pages=pages, page_size=16),
                max_model_len=pages * 16), pp, cost),
        PipelineSimulator(
            PipelineScheduler(
                ThrottleConfig(pipeline_depth=pp),
                PagedKVManager(num_pages=pages, page_size=16),
                max_model_len=pages * 16), pp, cost,
            straggler_stage=pp // 2, straggler_factor=4.0),
    ]
    router = ReplicaRouter(sims, policy="balanced", rebalance=rebalance)
    return SimCluster(sims, router, trace_dir=trace_dir)


class TestClusterMigration:
    def test_control_plane_completes_everything_and_moves_work(self):
        cluster = _hetero_cluster(RebalancePolicy(), pages=1536)
        arrivals = sample_requests(SHAREGPT, 150, 90.0, seed=0)
        finished = cluster.run(arrivals)
        assert len(finished) == 150
        rs = cluster.router.rebalance_stats
        assert rs.passes > 0
        assert rs.stolen + rs.migrated > 0
        assert rs.migrated > 0, "tight pool straggler must trigger migration"
        for sim in cluster.sims:
            sim.sched.check_invariants()
        # migrated requests kept their progress: every request's output is
        # exactly its sampled length (sim emits one token per decode tick —
        # a lost/recomputed token count would show up here)
        for r in finished:
            assert r.num_output_tokens == r.sampling.max_new_tokens \
                or r.state.value == "finished_stopped"

    def test_migration_events_round_trip_through_traces(self, tmp_path):
        from repro.runtime.trace import Trace, check_trace, replay_trace
        cluster = _hetero_cluster(RebalancePolicy(), pages=1536,
                                  trace_dir=str(tmp_path))
        arrivals = sample_requests(SHAREGPT, 150, 90.0, seed=0)
        finished = cluster.run(arrivals)
        assert cluster.router.rebalance_stats.migrated > 0
        for sim in cluster.sims:
            sim.recorder.close()
        per_replica = 0
        saw_migrate = 0
        for i in range(2):
            path = str(tmp_path / f"replica{i}.trace.jsonl")
            trace = Trace.load(path)
            saw_migrate += sum(1 for r in trace.records
                               if r["kind"] == "migrate")
            # strict replay + re-record byte-identity (the §9 guarantee:
            # replays stay bit-identical across migration events)
            report = check_trace(path)
            per_replica += len(report.finished)
        assert saw_migrate >= 2          # at least one out + one in
        assert per_replica == len(finished)

    def test_ewma_calibration_tracks_output_lengths(self):
        cluster = _hetero_cluster(RebalancePolicy())
        arrivals = sample_requests(SHAREGPT, 120, 60.0, seed=0)
        cluster.run(arrivals)
        router = cluster.router
        assert router._ewma_output is not None
        import numpy as np
        mean_out = float(np.mean([r.num_output_tokens
                                  for r in cluster.finished]))
        # debiased EWMA: within a factor ~2 of the workload mean, and the
        # decode weight tracks half of it (expected remaining length)
        assert 0.5 * mean_out <= router._ewma_output <= 2.0 * mean_out
        assert router.weights.decode_tokens == pytest.approx(
            max(1.0, router._ewma_output / 2.0))

    def test_forced_migration_via_public_api(self):
        cluster = _hetero_cluster(None)
        sims = cluster.sims
        arrivals = sample_requests(SHAREGPT, 20, 100.0, seed=1)
        for t, prompt, out_len in arrivals:
            for sim in sims:
                sim.run_until(t)
            sims[0].inject_request(t, prompt, out_len)
        # decode something on replica 0, then force-move one request (a
        # drain can be refused while its micro-batch is in flight — retry
        # over candidates and ticks like an operator would)
        rid = None
        for _ in range(50):
            sims[0].run_until(sims[0].backend.time + 0.2)
            for cand in list(sims[0].sched.running_decode):
                if cluster.router.migrate_request(cand.request_id, 0, 1):
                    rid = cand.request_id
                    break
            if rid is not None:
                break
        assert rid is not None, "no decode request became drainable"
        # source side is drained immediately; the KV payload rides the
        # modeled interconnect latency before the destination adopts it
        assert not sims[0].sched.kv.has_request(rid)
        assert cluster.router.has_in_transit
        cluster.router.control_tick(sims[0].backend.time + 1.0)
        assert sims[1].sched.kv.has_request(rid)
        finished = cluster.run([])
        assert len(finished) == 20
        assert any(r.request_id == rid for r in sims[1].metrics.finished)


class TestReplicaCapacity:
    def test_constructors_derive_scalars(self):
        assert ReplicaCapacity.scaled(2.5).scalar() == pytest.approx(0.4)
        # one of 4 stages 4x slower: pp/(pp-1+f) = 4/7
        assert ReplicaCapacity.straggler(4, 4.0).scalar() == \
            pytest.approx(4.0 / 7.0)
        assert ReplicaCapacity().scalar() == 1.0

    def test_router_accepts_mixed_hint_types(self):
        sims = [PipelineSimulator(make_sched(pp=3, pages=512), 3,
                                  cost_model_for(CFG, pp=3))
                for _ in range(2)]
        router = ReplicaRouter(
            sims, capacities=[1.0, ReplicaCapacity.scaled(2.0)])
        assert router.capacities == [1.0, 0.5]
        assert isinstance(router.capacity_hints[1], ReplicaCapacity)
