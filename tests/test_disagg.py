"""Disaggregated prefill/decode serving (DESIGN.md §15).

Four layers:

  * role vocabulary — `validate_roles` rejects malformed fleets; the spec
    layer round-trips roles/handoff through JSON exactly;
  * admission masking — decode-role replicas never receive new requests,
    under both routing policies;
  * handoff lifecycle corners — abort mid-handoff leaks nothing, a
    partially-prefilled request steals/hands off and resumes at the right
    chunk, a prefix-cache-adopted request survives a handoff;
  * recording — per-replica traces with `handoff` records strict-replay
    byte-identically (the engine-level bit-identity test lives in
    tests/test_engine_migration.py because it needs jax).
"""

import pytest

from repro.configs import get_config
from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    RequestState,
    SamplingParams,
    ThrottleConfig,
)
from repro.data.workload import SHAREGPT, sample_requests
from repro.runtime.disagg import (
    ROLE_DECODE,
    ROLE_MIXED,
    ROLE_PREFILL,
    ROLES,
    HandoffPolicy,
    decode_capable,
    prefill_capable,
    validate_roles,
)
from repro.runtime.router import ReplicaRouter, SimCluster
from repro.runtime.simulator import PipelineSimulator, cost_model_for

CFG = get_config("qwen2.5-14b")


def make_sim(pp=2, pages=512, page_size=8, caching=False,
             max_chunk_tokens=1 << 20):
    th = ThrottleConfig(pipeline_depth=pp, policy=PrefillPolicy.GLLM)
    kv = PagedKVManager(num_pages=pages, page_size=page_size,
                        enable_prefix_caching=caching)
    sched = PipelineScheduler(th, kv, max_model_len=pages * page_size,
                              max_chunk_tokens=max_chunk_tokens)
    return PipelineSimulator(sched, pp, cost_model_for(CFG, pp=pp))


def pd_cluster(*, pages=512, caching=False, handoff=None, trace_dir=None):
    """One prefill-role + one decode-role replica with the handoff plane."""
    sims = [make_sim(pages=pages, caching=caching),
            make_sim(pages=pages, caching=caching)]
    router = ReplicaRouter(
        sims, policy="balanced",
        roles=(ROLE_PREFILL, ROLE_DECODE),
        handoff=handoff or HandoffPolicy(interval=0.01,
                                         max_decode_tokens=8))
    return SimCluster(sims, router, trace_dir=trace_dir)


# ---------------------------------------------------------------------------
# role vocabulary + spec layer
# ---------------------------------------------------------------------------

class TestRoles:
    def test_vocabulary(self):
        assert ROLES == (ROLE_PREFILL, ROLE_DECODE, ROLE_MIXED)
        assert prefill_capable(ROLE_PREFILL) and prefill_capable(ROLE_MIXED)
        assert not prefill_capable(ROLE_DECODE)
        assert decode_capable(ROLE_DECODE) and decode_capable(ROLE_MIXED)
        assert not decode_capable(ROLE_PREFILL)

    def test_validate_rejects_unknown_role(self):
        with pytest.raises(ValueError, match="bogus"):
            validate_roles(("prefill", "bogus"), 2)

    def test_validate_rejects_wrong_length(self):
        with pytest.raises(ValueError, match="one role per replica"):
            validate_roles(("prefill", "decode"), 3)

    def test_validate_rejects_unservable_fleets(self):
        with pytest.raises(ValueError, match="no decode-capable"):
            validate_roles(("prefill", "prefill"), 2)
        with pytest.raises(ValueError, match="no prefill-capable"):
            validate_roles(("decode", "decode"), 2)

    def test_spec_round_trip_exact(self):
        from repro.serving import ClusterSpec, ServeSpec
        spec = ServeSpec(
            backend="sim",
            cluster=ClusterSpec(
                replicas=3, roles=("prefill", "mixed", "decode"),
                handoff=HandoffPolicy(interval=0.02, handoff_batch=4)))
        again = ServeSpec.from_json(spec.to_json())
        assert again == spec
        assert again.cluster.roles == ("prefill", "mixed", "decode")
        assert again.cluster.handoff == HandoffPolicy(interval=0.02,
                                                      handoff_batch=4)

    def test_spec_rejects_unknown_role_value(self):
        from repro.serving import ClusterSpec
        with pytest.raises(ValueError, match="unknown replica role"):
            ClusterSpec(replicas=2, roles=("prefill", "deocde"))


# ---------------------------------------------------------------------------
# admission masking
# ---------------------------------------------------------------------------

class TestAdmissionMasking:
    @pytest.mark.parametrize("policy", ["balanced", "rr"])
    def test_decode_replicas_never_admit(self, policy):
        sims = [make_sim() for _ in range(3)]
        router = ReplicaRouter(
            sims, policy=policy,
            roles=(ROLE_PREFILL, ROLE_DECODE, ROLE_MIXED),
            handoff=HandoffPolicy())
        for _ in range(12):
            assert router.select(64) != 1
        assert router.routed_counts[1] == 0
        assert sum(router.routed_counts) == 12


# ---------------------------------------------------------------------------
# handoff lifecycle
# ---------------------------------------------------------------------------

def _no_kv_left(cluster):
    for sim in cluster.sims:
        assert sim.sched.kv.num_free_pages == sim.sched.kv.num_pages


class TestHandoffLifecycle:
    def test_requests_hand_off_and_finish(self):
        cluster = pd_cluster()
        arrivals = [(t, p, o) for t, p, o in
                    sample_requests(SHAREGPT, 30, 40.0, seed=3)]
        finished = cluster.run(arrivals)
        assert len(finished) == 30
        st = cluster.router.disagg_stats
        assert st.handoffs > 0 and st.handoff_tokens > 0
        assert st.fallbacks == 0
        # the decode replica did the decoding it was handed
        assert cluster.sims[1].sched.stats.tokens_retired > 0
        for sim in cluster.sims:
            sim.sched.check_invariants()
        for r in finished:
            assert r.num_output_tokens == r.sampling.max_new_tokens \
                or r.state.value == "finished_stopped"
        _no_kv_left(cluster)

    def test_each_request_hands_off_at_most_policy_times(self):
        cluster = pd_cluster()
        arrivals = sample_requests(SHAREGPT, 30, 40.0, seed=3)
        cluster.run(arrivals)
        # counters are popped as requests finish; the policy cap held if
        # handoffs never exceeded the request count
        assert cluster.router.disagg_stats.handoffs <= 30

    def test_abort_mid_handoff_drops_delivery_without_leaks(self):
        cluster = pd_cluster()
        sims, router = cluster.sims, cluster.router
        arrivals = sample_requests(SHAREGPT, 8, 100.0, seed=5)
        for t, prompt, out_len in arrivals:
            sims[0].inject_request(t, prompt, out_len)
        # decode something on the prefill replica, then hand it off so the
        # KV payload is in transit
        rid = None
        for _ in range(80):
            sims[0].run_until(sims[0].backend.time + 0.05)
            for cand in list(sims[0].sched.running_decode):
                if router._move_request(cand.request_id, 0, 1,
                                        kind="handoff"):
                    rid = cand.request_id
                    break
            if rid is not None:
                break
        assert rid is not None, "no decode request became drainable"
        assert router.has_in_transit
        assert not sims[0].sched.kv.has_request(rid)
        # abort while the payload rides the interconnect
        assert router.abort_request(rid)
        assert not router.has_in_transit       # delivery dropped
        assert rid not in router._handoffs_of  # counter retired
        # nothing may land later: run the cluster dry and check both pools
        finished = cluster.run([])
        assert not sims[1].sched.kv.has_request(rid)
        aborted = [r for r in finished if r.request_id == rid]
        assert len(aborted) == 1
        assert aborted[0].state is RequestState.FINISHED_ABORTED
        assert len(finished) == 8
        for sim in sims:
            sim.sched.check_invariants()
        _no_kv_left(cluster)

    def test_steal_of_partially_prefilled_request(self):
        # chunk cap forces the prompt through many prefill ticks, opening
        # drainable windows between chunk retire and next dispatch
        sims = [make_sim(max_chunk_tokens=256), make_sim()]
        router = ReplicaRouter(sims, policy="balanced")
        cluster = SimCluster(sims, router)
        prompt = list(range(1, 1501))
        sims[0].inject_request(0.0, prompt, 12)
        rid = None
        for _ in range(400):
            sims[0].step()
            for cand in list(sims[0].sched.running_prefill):
                if 0 < cand.num_prefilled < cand.num_effective_prompt_tokens:
                    if router.migrate_request(cand.request_id, 0, 1):
                        rid = cand.request_id
                        break
            if rid is not None:
                break
        assert rid is not None, "never caught the request mid-prefill"
        router.control_tick(sims[0].backend.time + 1.0)  # deliver
        req = next(r for r in list(sims[1].sched.running_prefill)
                   + list(sims[1].sched.waiting) if r.request_id == rid)
        # progress moved with it: the destination resumes at the chunk
        # cursor, with exactly the prefilled KV resident
        assert req.num_prefilled > 0
        assert sims[1].sched.kv.num_tokens(rid) == req.num_prefilled
        finished = cluster.run([])
        assert len(finished) == 1
        assert finished[0].num_output_tokens == 12
        for sim in sims:
            sim.sched.check_invariants()
        _no_kv_left(cluster)

    def test_handoff_of_prefix_adopted_request(self):
        cluster = pd_cluster(caching=True)
        sims, router = cluster.sims, cluster.router
        prefix = list(range(1, 129))           # 16 full pages of 8
        first = (0.0, prefix + [200, 201, 202], 4)
        second = (1.0, prefix + [300, 301, 302], 12)
        finished = cluster.run([first, second])
        assert len(finished) == 2
        sched0 = sims[0].sched
        assert sched0.stats.prefix_hits >= 1   # second adopted the head
        assert sched0.stats.prefix_tokens_avoided > 0
        assert router.disagg_stats.handoffs >= 1
        for r in finished:
            assert r.num_output_tokens == r.sampling.max_new_tokens
        for sim in sims:
            sim.sched.check_invariants()

    def test_handoff_records_strict_replay(self, tmp_path):
        from repro.runtime.trace import Trace, check_trace
        cluster = pd_cluster(trace_dir=str(tmp_path))
        arrivals = sample_requests(SHAREGPT, 24, 40.0, seed=7)
        finished = cluster.run(arrivals)
        assert cluster.router.disagg_stats.handoffs > 0
        for sim in cluster.sims:
            sim.recorder.close()
        cluster.router.close_trace()
        saw_handoff = 0
        per_replica = 0
        for i in range(2):
            path = str(tmp_path / f"replica{i}.trace.jsonl")
            trace = Trace.load(path)
            saw_handoff += sum(1 for r in trace.records
                               if r["kind"] == "handoff")
            # strict replay + re-record byte-identity through handoff
            # records (the §15 guarantee, same bar as §9 migration)
            report = check_trace(path)
            per_replica += len(report.finished)
        assert saw_handoff >= 2        # at least one out + one in
        assert per_replica == len(finished)
        # the router stream declares the fleet shape and the moves
        router_trace = Trace.load(str(tmp_path / "router.trace.jsonl"),
                                  expect="gllm-route")
        assert router_trace.header["roles"] == ["prefill", "decode"]
        assert "handoff" in router_trace.header
        assert any(r.get("kind") == "handoff"
                   for r in router_trace.records)


# ---------------------------------------------------------------------------
# serving surface (spec -> build -> stats)
# ---------------------------------------------------------------------------

class TestServingSurface:
    def test_stats_surface_roles_and_handoffs(self):
        from repro.serving import ClusterSpec, ServeSpec, SimSpec, build
        from repro.serving.http import stats_to_json
        spec = ServeSpec(
            backend="sim",
            sim=SimSpec(pp=2, pages=512, page_size=8),
            cluster=ClusterSpec(
                replicas=2, roles=("prefill", "decode"),
                handoff=HandoffPolicy(interval=0.01, max_decode_tokens=8)))
        server = build(spec)
        arrivals = sample_requests(SHAREGPT, 16, 40.0, seed=1)
        server.engine.run(arrivals)
        stats = server.stats()
        assert [r.role for r in stats.replicas] == ["prefill", "decode"]
        assert stats.disagg is not None and stats.disagg.handoffs > 0
        depth = stats.queue_depth_by_role
        assert set(depth) == {"prefill", "decode"}
        assert depth["prefill"]["replicas"] == 1
        js = stats_to_json(stats)
        assert js["disagg"]["handoffs"] == stats.disagg.handoffs
        assert js["queue_depth_by_role"] == depth
        assert [r["role"] for r in js["replicas"]] == ["prefill", "decode"]

    def test_role_less_cluster_reports_mixed(self):
        from repro.serving import ClusterSpec, ServeSpec, SimSpec, build
        spec = ServeSpec(backend="sim",
                         sim=SimSpec(pp=2, pages=512, page_size=8),
                         cluster=ClusterSpec(replicas=2))
        stats = build(spec).stats()
        assert [r.role for r in stats.replicas] == ["mixed", "mixed"]
        assert stats.disagg is None
