"""Scheduler behaviour: conservation, balance, preemption, fault paths."""

import random

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    Request,
    SamplingParams,
    ThrottleConfig,
)


def make_sched(policy=PrefillPolicy.GLLM, pages=256, page=16, pp=4,
               max_p=64, min_p=8, T=4, **kw):
    cfg = ThrottleConfig(num_iters_T=T, max_prefill_tokens=max_p,
                         min_prefill_tokens=min_p, pipeline_depth=pp,
                         policy=policy)
    kv = PagedKVManager(pages, page)
    return PipelineScheduler(cfg, kv, max_model_len=page * 1024, **kw), kv


def drive(sched, reqs, pp=4, max_ticks=3000, tokens_fn=lambda seq: 7):
    """Simulated pipeline of depth pp: complete batches pp ticks later."""
    inflight = []
    for t in range(max_ticks):
        if not sched.has_work:
            break
        b = sched.schedule(now=float(t))
        inflight.append(b)
        if len(inflight) >= pp:
            done = inflight.pop(0)
            toks = [tokens_fn(s) for s in done.seqs if s.produces_token]
            sched.complete(done.batch_id, toks, now=float(t))
        sched.check_invariants()
    for done in inflight:
        toks = [tokens_fn(s) for s in done.seqs if s.produces_token]
        sched.complete(done.batch_id, toks)
    return t


class TestLifecycle:
    def test_all_requests_finish_and_conserve_tokens(self):
        sched, kv = make_sched()
        rng = random.Random(0)
        reqs = [Request(f"r{i}", [1] * rng.randint(5, 200),
                        SamplingParams(max_new_tokens=rng.randint(1, 20)))
                for i in range(20)]
        for r in reqs:
            sched.add_request(r)
        drive(sched, reqs)
        for r in reqs:
            assert r.is_finished
            assert r.num_output_tokens == r.sampling.max_new_tokens
        # token conservation (no preemptions in this sizing): every prompt
        # token is prefilled exactly once; every output token after the first
        # is one decode step
        assert sched.stats.preemptions == 0
        total_prefill = sum(sched.stats.scheduled_prefill_tokens)
        total_decode = sum(sched.stats.scheduled_decode_tokens)
        assert total_prefill == sum(r.num_prompt_tokens for r in reqs)
        assert total_decode == sum(r.num_output_tokens - 1 for r in reqs)
        assert kv.kv_free_rate == 1.0                  # everything freed

    def test_decode_balance_eq4(self):
        """Once all requests are decoding, per-tick decode counts differ by
        at most ceil(RD/pp) - floor(RD/pp) <= 1 (the paper's even spread)."""
        sched, _ = make_sched(pp=4, max_p=4096, T=1)
        reqs = [Request(f"r{i}", [1] * 8, SamplingParams(max_new_tokens=50))
                for i in range(16)]
        for r in reqs:
            sched.add_request(r)
        drive(sched, reqs, pp=4)
        counts = sched.stats.scheduled_decode_tokens
        # steady-state window: all 16 decoding -> 4 per micro-batch
        steady = [c for c in counts if c > 0]
        assert steady and max(steady) <= 4 + 1

    def test_stop_token_finishes_early(self):
        sched, _ = make_sched()
        r = Request("r0", [1] * 10,
                    SamplingParams(max_new_tokens=100, stop_token_ids=(7,)))
        sched.add_request(r)
        drive(sched, [r])
        assert r.state.name == "FINISHED_STOPPED"
        assert r.num_output_tokens == 1

    def test_in_flight_exclusion(self):
        """A request never sits in two in-flight micro-batches."""
        sched, _ = make_sched(pp=4)
        reqs = [Request(f"r{i}", [1] * 30, SamplingParams(max_new_tokens=10))
                for i in range(4)]
        for r in reqs:
            sched.add_request(r)
        inflight = []
        for t in range(40):
            b = sched.schedule(float(t))
            ids = [s.request.request_id for s in b.seqs]
            for other in inflight:
                other_ids = {s.request.request_id for s in other.seqs}
                assert not (set(ids) & other_ids)
            inflight.append(b)
            if len(inflight) >= 4:
                d = inflight.pop(0)
                sched.complete(d.batch_id,
                               [7] * sum(1 for s in d.seqs
                                         if s.produces_token), float(t))


class TestPreemption:
    def test_preempts_latest_under_kv_pressure(self):
        sched, kv = make_sched(pages=16, page=4, pp=2, max_p=16, min_p=4)
        a = Request("a", [1] * 12, SamplingParams(max_new_tokens=30))
        b = Request("b", [1] * 12, SamplingParams(max_new_tokens=30))
        sched.add_request(a)
        sched.add_request(b)
        drive(sched, [a, b], pp=2)
        assert a.is_finished and b.is_finished
        # 16 pages x4 = 64 slots < 2x42 peak demand => preemption occurred
        assert sched.stats.preemptions >= 1
        assert b.metrics.num_preemptions >= 1 or a.metrics.num_preemptions >= 1
        assert kv.kv_free_rate == 1.0

    def test_unservable_request_rejected_at_admission(self):
        sched, _ = make_sched(pages=4, page=4)
        with pytest.raises(ValueError):
            sched.add_request(
                Request("big", [1] * 10, SamplingParams(max_new_tokens=20)))

    def test_abort_batch_requeues(self):
        sched, kv = make_sched()
        r = Request("a", [1] * 40, SamplingParams(max_new_tokens=5))
        sched.add_request(r)
        b = sched.schedule(0.0)
        assert not b.is_empty
        affected = sched.abort_batch(b.batch_id)
        assert r in affected
        assert r in sched.waiting and r.num_prefilled == 0
        sched.check_invariants()
        drive(sched, [r])
        assert r.is_finished


class TestPolicies:
    def test_sarathi_decode_first_fixed_budget(self):
        sched, _ = make_sched(policy=PrefillPolicy.SARATHI, max_p=64)
        reqs = [Request(f"r{i}", [1] * 100, SamplingParams(max_new_tokens=30))
                for i in range(8)]
        for r in reqs:
            sched.add_request(r)
        for t in range(6):
            b = sched.schedule(float(t))
            assert b.num_tokens <= 64           # fixed token budget
            sched.complete(b.batch_id, [7] * sum(
                1 for s in b.seqs if s.produces_token), float(t))

    def test_gllm_suspends_prefill_below_threshold(self):
        sched, kv = make_sched(pages=10, page=4, pp=2)
        kv.allocate("hog", 38)                  # free rate = 0.05 < usable
        r = Request("a", [1] * 8, SamplingParams(max_new_tokens=2))
        sched.add_request(r)
        b = sched.schedule(0.0)
        assert b.num_prefill_tokens == 0        # UT threshold blocks admission


class TestBatchLookup:
    """Public `get_batch` API — the execution layer's handle on ring ids."""

    def test_get_batch_resolves_until_complete(self):
        sched, _ = make_sched()
        r = Request("a", [1] * 12, SamplingParams(max_new_tokens=3))
        sched.add_request(r)
        b = sched.schedule(0.0)
        assert not b.is_empty
        assert sched.get_batch(b.batch_id) is b
        assert sched.active_batch_ids() == [b.batch_id]
        toks = [7] * sum(1 for s in b.seqs if s.produces_token)
        sched.complete(b.batch_id, toks, 0.0)
        assert sched.get_batch(b.batch_id) is None
        assert sched.active_batch_ids() == []

    def test_get_batch_unknown_or_aborted_is_none(self):
        sched, _ = make_sched()
        assert sched.get_batch(12345) is None
        r = Request("a", [1] * 12, SamplingParams(max_new_tokens=3))
        sched.add_request(r)
        b = sched.schedule(0.0)
        sched.abort_batch(b.batch_id)
        assert sched.get_batch(b.batch_id) is None

    def test_in_flight_ids_match_active_batches(self):
        sched, _ = make_sched(pp=3)
        reqs = [Request(f"r{i}", [1] * 20, SamplingParams(max_new_tokens=4))
                for i in range(3)]
        for r in reqs:
            sched.add_request(r)
        ids = [sched.schedule(float(t)).batch_id for t in range(3)]
        assert set(sched.active_batch_ids()) == set(ids)
        for bid in ids:
            batch = sched.get_batch(bid)
            for seq in batch.seqs:
                assert sched._in_flight[seq.request.request_id] == bid


class TestPreemptionCallback:
    def test_on_preempt_fires_on_kv_pressure_and_abort(self):
        sched, kv = make_sched(pages=16, page=4, pp=2, max_p=16, min_p=4)
        evicted = []
        sched.on_preempt = lambda req: evicted.append(req.request_id)
        a = Request("a", [1] * 12, SamplingParams(max_new_tokens=30))
        b = Request("b", [1] * 12, SamplingParams(max_new_tokens=30))
        sched.add_request(a)
        sched.add_request(b)
        drive(sched, [a, b], pp=2)
        assert sched.stats.preemptions >= 1
        assert len(evicted) == sched.stats.preemptions
        # abort path notifies too
        sched2, _ = make_sched()
        gone = []
        sched2.on_preempt = lambda req: gone.append(req.request_id)
        r = Request("x", [1] * 30, SamplingParams(max_new_tokens=5))
        sched2.add_request(r)
        bt = sched2.schedule(0.0)
        sched2.abort_batch(bt.batch_id)
        assert gone == ["x"]


class TestSLOClasses:
    """SLO-class-aware Token Throttling (DESIGN.md §11): admission order and
    preemption-victim choice honor slo_class/priority; all-default queues
    behave exactly like the pre-SLO FCFS scheduler."""

    def test_interactive_admitted_ahead_of_earlier_batch(self):
        sched, kv = make_sched(max_p=32)
        batch = Request("b", [1] * 64,
                        SamplingParams(max_new_tokens=4, slo_class="batch"))
        inter = Request("i", [1] * 64, SamplingParams(max_new_tokens=4))
        sched.add_request(batch)            # batch arrives FIRST
        sched.add_request(inter)
        b = sched.schedule(0.0)
        # the tight eq. 3 budget goes to the interactive request
        assert [s.request.request_id for s in b.prefill] == ["i"]

    def test_priority_orders_within_class(self):
        sched, kv = make_sched(max_p=32)
        for rid, prio in (("low", 0), ("high", 5)):
            sched.add_request(Request(
                rid, [1] * 64, SamplingParams(max_new_tokens=4,
                                              priority=prio)))
        b = sched.schedule(0.0)
        assert [s.request.request_id for s in b.prefill] == ["high"]
        assert sched.admission_order()[0].request_id == "low"

    def test_all_default_queue_stays_fcfs(self):
        sched, kv = make_sched(max_p=512)
        for i in range(4):
            sched.add_request(Request(f"r{i}", [1] * 16,
                                      SamplingParams(max_new_tokens=2)))
        order = [r.request_id for r in sched.admission_order()]
        assert order == ["r0", "r1", "r2", "r3"]

    def _decode_resident(self, sched, rid, slo, n_prompt=8):
        req = Request(rid, [1] * n_prompt,
                      SamplingParams(max_new_tokens=32, slo_class=slo))
        sched.add_request(req)
        b = sched.schedule(0.0)
        toks = [7 for s in b.seqs if s.produces_token]
        sched.complete(b.batch_id, toks, now=0.0)
        assert req in sched.running_decode
        return req

    def test_preemption_victims_chosen_batch_first(self):
        sched, kv = make_sched(max_p=512)
        batch = self._decode_resident(sched, "b", "batch")
        inter = self._decode_resident(sched, "i", "interactive")
        # latest-arrival-first alone would victimize "i"; class order wins
        victim = sched._pick_preemption_victim(exclude=set())
        assert victim is batch

    def test_interactive_victimized_only_after_batch_exhausted(self):
        sched, kv = make_sched(max_p=512)
        batch = self._decode_resident(sched, "b", "batch")
        inter = self._decode_resident(sched, "i", "interactive")
        victim = sched._pick_preemption_victim(exclude={"b"})
        assert victim is inter


def _property_body(n, seed, policy):
    rng = random.Random(seed)
    sched, kv = make_sched(policy=policy, pages=128, page=8, pp=3,
                           max_p=48, min_p=4, T=3)
    reqs = [Request(f"r{i}", [1] * rng.randint(1, 120),
                    SamplingParams(max_new_tokens=rng.randint(1, 16)))
            for i in range(n)]
    for r in reqs:
        sched.add_request(r)
    drive(sched, reqs, pp=3)
    assert all(r.is_finished for r in reqs)
    assert kv.kv_free_rate == 1.0


if HAS_HYPOTHESIS:
    @given(n=st.integers(1, 12), seed=st.integers(0, 10**6),
           policy=st.sampled_from(list(PrefillPolicy)))
    @settings(max_examples=40, deadline=None)
    def test_property_never_deadlocks_and_finishes(n, seed, policy):
        _property_body(n, seed, policy)
else:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_property_never_deadlocks_and_finishes(seed):
        # fallback spot-check without hypothesis (requirements-dev.txt)
        for policy in PrefillPolicy:
            _property_body(6, seed, policy)
