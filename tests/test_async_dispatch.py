"""Async double-buffered dispatch and bucketed serve shapes (DESIGN.md §12).

The four dispatch/shape variants of `PipelineEngine` — sync/async ×
fixed/bucketed — are pure execution strategies: they may change *when* a
tick's tokens are read back and *how much* padding a tick carries, never
the tokens themselves.  These tests pin that bit-identity, the
async+trace incompatibility, the zero-recompiles-in-steady-state contract
of the bucket ladder, and the drain/submit race on traced engines.
"""

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, make_reduced
from repro.core import SamplingParams, ThrottleConfig
from repro.models import transformer as tfm
from repro.models.serve import ServeDims
from repro.runtime.engine import PipelineEngine

VARIANTS = {
    "sync_fixed": dict(async_dispatch=False, bucketed=False),
    "sync_bucketed": dict(async_dispatch=False, bucketed=True),
    "async_fixed": dict(async_dispatch=True, bucketed=False),
    "async_bucketed": dict(async_dispatch=True, bucketed=True),
}


def build(arch="qwen1.5-0.5b", *, C=16, max_p=16, **engine_kw):
    cfg = make_reduced(get_config(arch)).with_plan(pp=1, tp=1,
                                                   ep_over_data=False)
    cf = float(max(cfg.num_experts, 1))   # dropless MoE: keep outputs exact
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=cf)
    mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    dims = ServeDims(Sp=1, C=C, Sd=8, pages=256, page=8, Bp=32, Bd=32,
                     slots=16, Te=0)
    with jax.set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.key(0), dtype=jnp.float32)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, tfm.param_pspecs(cfg),
            is_leaf=lambda x: isinstance(x, P))
        th = ThrottleConfig(pipeline_depth=1, max_prefill_tokens=max_p,
                            min_prefill_tokens=4, num_iters_T=2)
        eng = PipelineEngine(cfg, dims, params, mesh, th, **engine_kw)
    return cfg, params, eng


def mixed_workload(cfg, eng):
    """Two waves with single-chunk, multi-chunk, and decode-heavy requests,
    interleaved with service so the ring sees bubbles and partial batches
    (every bucket class for the ladder, retirement lag for async)."""
    rng = np.random.default_rng(5)
    reqs = []
    for wave in ((7, 23, 37), (12, 5, 30)):
        for n in wave:
            reqs.append(eng.add_request(
                list(rng.integers(0, cfg.vocab_size, int(n))),
                SamplingParams(max_new_tokens=6)))
        for _ in range(4):
            eng.step()
    eng.drain(max_ticks=2000)
    assert all(r.is_finished for r in reqs), [r.state for r in reqs]
    return [r.output_token_ids for r in reqs]


def test_all_variants_bit_identical():
    """Padding shape and retirement timing must never change greedy tokens
    (the Table-1 claim extended to the dispatch layer)."""
    outs = {}
    for name, kw in VARIANTS.items():
        cfg, _, eng = build(**kw)
        outs[name] = mixed_workload(cfg, eng)
    for name in VARIANTS:
        assert outs[name] == outs["sync_fixed"], name


def test_async_dispatch_rejects_tracing():
    """Deferred retirement would interleave trace records out of order, so
    the ctor refuses the combination up front."""
    cfg = make_reduced(get_config("qwen1.5-0.5b")).with_plan(
        pp=1, tp=1, ep_over_data=False)
    dims = ServeDims(Sp=1, C=16, Sd=8, pages=256, page=8, Bp=32, Bd=32,
                     slots=16)
    th = ThrottleConfig(pipeline_depth=1, num_iters_T=2)
    with pytest.raises(ValueError, match="async_dispatch"):
        PipelineEngine(cfg, dims, None, None, th,
                       trace_path="unused.jsonl", async_dispatch=True)


def test_bucketed_zero_recompiles_after_warm():
    """`warm_start` (run by the ctor for bucketed engines) compiles the
    whole ladder; serving any mixed workload afterwards must not add a
    single compilation — the static-shape contract that keeps tick latency
    flat in steady state."""
    cfg, _, eng = build(bucketed=True)
    warm = eng.backend.compile_count()
    assert warm > 0
    mixed_workload(cfg, eng)
    assert eng.backend.stats.ticks > 0
    assert eng.backend.compile_count() == warm, \
        "bucketed serving recompiled after warm_start"


def test_bucketed_reduces_padded_tokens():
    """The point of the ladder: strictly fewer padded tokens than the
    fixed full-cell shape on the same workload."""
    padded = {}
    for name in ("sync_fixed", "sync_bucketed"):
        cfg, _, eng = build(**VARIANTS[name])
        mixed_workload(cfg, eng)
        st = eng.backend.stats
        padded[name] = st.padded_prefill + st.padded_decode
    assert padded["sync_bucketed"] < padded["sync_fixed"]


def test_depth_buckets_engage_and_never_recompile():
    """Deep-context requests walk the ladder's depth dimension (Bp/Bd) up
    from the shallow steps without a single post-warm compile, and shallow
    ticks actually select sub-full tables (scanned < full-width scan)."""
    cfg, _, eng = build(bucketed=True, C=16, max_p=16)
    warm = eng.backend.compile_count()
    rng = np.random.default_rng(3)
    # grows past page*Bd/4 = 64 tokens of context → crosses depth steps
    long = eng.add_request(list(rng.integers(0, cfg.vocab_size, 80)),
                           SamplingParams(max_new_tokens=40))
    short = eng.add_request(list(rng.integers(0, cfg.vocab_size, 5)),
                            SamplingParams(max_new_tokens=4))
    seen_bd = set()
    for _ in range(2000):
        if not (eng.has_work or eng.busy):
            break
        eng.step()
        if eng.stats.last_bucket is not None:
            seen_bd.add(eng.stats.last_bucket["Bd"])
    assert long.is_finished and short.is_finished
    assert len(seen_bd) > 1, f"depth never stepped: {seen_bd}"
    st = eng.backend.stats
    full_scan = st.ticks * (eng.dims.Sp * eng.dims.Bp
                            + eng.dims.Sd * eng.dims.Bd)
    assert st.scanned_pages < full_scan
    assert 0 < st.live_pages <= st.scanned_pages
    assert eng.backend.compile_count() == warm, \
        "depth bucketing recompiled after warm_start"


def test_async_tick_count_matches_sync():
    """Regression for the async tick inflation (51 vs 36 device ticks on the
    bench workload): with the readiness probe retiring finished batches
    before scheduling, async dispatch must not pay materially more device
    ticks than sync on the same workload."""
    ticks = {}
    for name in ("sync_bucketed", "async_bucketed"):
        cfg, _, eng = build(**VARIANTS[name])
        mixed_workload(cfg, eng)
        ticks[name] = eng.backend.stats.ticks
    # identical on CPU (readback is ready by the next step); the small slack
    # absorbs a genuinely in-flight device tick on real accelerators
    assert ticks["async_bucketed"] <= ticks["sync_bucketed"] * 1.15 + 2, ticks


def test_traced_drain_races_submissions(tmp_path):
    """Regression for the drain/submit race: `drain` checks has-work and
    ticks under ONE trace-lock acquisition, so a request submitted from
    another thread mid-drain is either served by this drain pass or left
    cleanly queued — and the recorded trace stays strictly replayable."""
    from repro.runtime.trace import Trace, replay_trace

    path = str(tmp_path / "race.trace.jsonl")
    cfg, _, eng = build(trace_path=path)
    rng = np.random.default_rng(9)
    prompts = [list(rng.integers(0, cfg.vocab_size, int(n)))
               for n in (6, 14, 9, 21, 11)]
    reqs = [eng.add_request(prompts[0], SamplingParams(max_new_tokens=4))]
    done = threading.Event()

    def submit():
        for p in prompts[1:]:
            time.sleep(0.002)
            reqs.append(eng.add_request(p, SamplingParams(max_new_tokens=4)))
        done.set()

    t = threading.Thread(target=submit)
    t.start()
    while not done.is_set() or eng.has_work or eng.busy:
        eng.drain(max_ticks=50)
    t.join()
    assert all(r.is_finished for r in reqs)
    eng.recorder.close()

    report = replay_trace(Trace.load(path))     # strict: decisions must match
    assert report.outputs() == {r.request_id: list(r.output_token_ids)
                                for r in reqs}
