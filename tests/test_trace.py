"""Trace record/replay (runtime/trace.py): golden-trace regression tests.

The checked-in fixtures under tests/fixtures/traces/ are SimBackend runs of
the real scheduler; strict replay re-derives every batch decision from the
recorded workload and asserts it matches.  Any behavior change in
core/throttle.py, core/scheduler.py, or the TickLoop therefore fails here
with the exact tick and field that moved — regenerate the fixtures
(make_fixtures.py) and review the diff to accept a deliberate change.
"""

import copy
import dataclasses
import importlib.util
import io
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

from repro.configs import get_config
from repro.core import (
    PagedKVManager,
    PipelineScheduler,
    PrefillPolicy,
    ThrottleConfig,
)
from repro.data.workload import WorkloadSpec, sample_requests
from repro.runtime.simulator import (
    CostModel,
    cost_model_for,
    record_sim_trace,
)
from repro.runtime.trace import (
    SCHEMA_MAJOR,
    Trace,
    TraceBackend,
    TraceDivergence,
    TraceSchemaError,
    calibration_error,
    check_trace,
    replay_trace,
    scheduler_from_header,
    tick_samples,
)

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures", "traces")
FIXTURES = ["prefill_heavy.trace.jsonl", "decode_saturated.trace.jsonl"]


def fixture_path(name):
    return os.path.join(FIXTURE_DIR, name)


def load_fixture(name) -> Trace:
    return Trace.load(fixture_path(name))


def _make_fixtures_module():
    spec = importlib.util.spec_from_file_location(
        "make_fixtures", os.path.join(FIXTURE_DIR, "make_fixtures.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# Round-trip determinism (ISSUE acceptance)
# ---------------------------------------------------------------------------

class TestRoundTrip:
    @pytest.mark.parametrize("name", FIXTURES)
    def test_record_replay_round_trip_is_bit_identical(self, name):
        """Strict replay, itself recorded, reproduces the original file
        byte for byte — decisions, budgets, latencies, tokens, floats."""
        with open(fixture_path(name)) as fh:
            original = fh.read()
        report = replay_trace(Trace.loads(original), record=True)
        assert report.recorded.dumps() == original

    @pytest.mark.parametrize("name", FIXTURES)
    def test_two_replays_agree_exactly(self, name):
        trace = load_fixture(name)
        a = replay_trace(trace)
        b = replay_trace(trace)
        assert len(a.finished) == len(trace.requests) > 0
        assert a.request_metrics() == b.request_metrics()
        assert a.outputs() == b.outputs()

    @pytest.mark.parametrize("name", FIXTURES)
    def test_check_trace_cli_gate(self, name):
        report = check_trace(fixture_path(name))
        assert report.ticks == len(load_fixture(name).ticks)

    def test_fixtures_regenerate_byte_identical(self):
        """make_fixtures.py with the pinned seeds reproduces the checked-in
        files — the fixtures and their generator cannot drift apart."""
        mod = _make_fixtures_module()
        for name, kw in mod.FIXTURES.items():
            sink = io.StringIO()
            mod.generate(sink, **kw)
            with open(fixture_path(name)) as fh:
                assert sink.getvalue() == fh.read(), name


# ---------------------------------------------------------------------------
# Schema versioning
# ---------------------------------------------------------------------------

class TestSchema:
    def test_header_carries_current_version(self):
        trace = load_fixture(FIXTURES[0])
        assert trace.header["schema"] == "gllm-trace"
        assert trace.header["version"][0] == SCHEMA_MAJOR

    def test_unknown_major_rejected(self):
        text = open(fixture_path(FIXTURES[0])).read()
        lines = text.splitlines()
        header = json.loads(lines[0])
        header["version"] = [SCHEMA_MAJOR + 1, 0]
        bad = "\n".join([json.dumps(header)] + lines[1:])
        with pytest.raises(TraceSchemaError, match="major"):
            Trace.loads(bad)

    def test_newer_minor_accepted(self):
        text = open(fixture_path(FIXTURES[0])).read()
        lines = text.splitlines()
        header = json.loads(lines[0])
        header["version"] = [SCHEMA_MAJOR, 99]
        Trace.loads("\n".join([json.dumps(header)] + lines[1:]))

    def test_missing_header_rejected(self):
        with pytest.raises(TraceSchemaError):
            Trace.loads('{"kind":"tick","tick":0}')
        with pytest.raises(TraceSchemaError):
            Trace.loads("")

    def test_route_stream_is_not_a_tick_trace(self):
        with pytest.raises(TraceSchemaError):
            Trace.loads('{"kind":"header","schema":"gllm-route",'
                        '"version":[1,0]}')


# ---------------------------------------------------------------------------
# Divergence reporting
# ---------------------------------------------------------------------------

class TestDivergence:
    def _tamper(self, trace: Trace, pred, mutate) -> Trace:
        t = Trace(copy.deepcopy(trace.header), copy.deepcopy(trace.records))
        for rec in t.records:
            if rec["kind"] == "tick" and pred(rec):
                mutate(rec)
                return t
        raise AssertionError("no tick matched")

    def test_divergence_names_exact_tick_and_field(self):
        trace = load_fixture("prefill_heavy.trace.jsonl")
        # grow the recorded first prefill chunk of some mid-trace tick
        def has_prefill(rec):
            return rec["tick"] >= 5 and rec["batch"] \
                and rec["batch"]["prefill"]
        bad = self._tamper(trace, has_prefill,
                           lambda rec: rec["batch"]["prefill"][0].__setitem__(
                               2, rec["batch"]["prefill"][0][2] + 1))
        tampered_tick = next(r["tick"] for r in bad.ticks
                             if has_prefill(r))
        with pytest.raises(TraceDivergence) as ei:
            replay_trace(bad)
        assert ei.value.tick == tampered_tick
        assert any(f == "batch.prefill" for f, _, _ in ei.value.diffs)
        assert f"tick {tampered_tick}" in str(ei.value)

    def test_divergence_on_budget_field(self):
        trace = load_fixture("decode_saturated.trace.jsonl")
        bad = self._tamper(trace, lambda rec: rec["tick"] == 17,
                           lambda rec: rec.update(
                               decode_budget=rec["decode_budget"] + 3))
        with pytest.raises(TraceDivergence) as ei:
            replay_trace(bad)
        assert ei.value.tick == 17
        assert [f for f, _, _ in ei.value.diffs] == ["decode_budget"]

    def test_truncated_trace_reports_pending_work(self):
        trace = load_fixture("prefill_heavy.trace.jsonl")
        cut = Trace(trace.header, trace.records[: len(trace.records) // 2])
        with pytest.raises(TraceDivergence):
            replay_trace(cut)

    def test_timing_only_tolerates_divergence(self):
        """What-if replay: same workload and recorded latencies, different
        policy — no assertions, every request still completes."""
        trace = load_fixture("decode_saturated.trace.jsonl")
        sched = scheduler_from_header(trace.header)
        sarathi = dataclasses.replace(sched.cfg,
                                      policy=PrefillPolicy.SARATHI)
        what_if = PipelineScheduler(sarathi, sched.kv,
                                    max_model_len=sched.max_model_len)
        report = replay_trace(trace, mode=TraceBackend.TIMING,
                              scheduler=what_if)
        assert len(report.finished) == len(trace.requests)
        assert report.mode == TraceBackend.TIMING


# ---------------------------------------------------------------------------
# Golden scheduler/throttle regression (satellite: budget decisions)
# ---------------------------------------------------------------------------

class TestGoldenBudgets:
    @pytest.mark.parametrize("name", FIXTURES)
    def test_replayed_budgets_match_recording(self, name):
        """The eq. 3/4 outputs per tick are pinned by the fixtures: a change
        to core/throttle.py or core/scheduler.py that alters batching shows
        up here as a reviewed fixture diff, not a silent behavior change."""
        trace = load_fixture(name)
        report = replay_trace(trace)
        stats = report.scheduler.stats
        assert stats.prefill_budgets == [r["prefill_budget"]
                                         for r in trace.ticks]
        assert stats.decode_budgets == [r["decode_budget"]
                                        for r in trace.ticks]
        assert stats.kv_free_rate == [r["kv_free"] for r in trace.ticks]

    def test_decode_fixture_exercises_pressure_paths(self):
        """The decode-saturated fixture must keep covering the interesting
        scheduler paths (UT gating + preemption) — guard against a
        regenerated fixture silently losing coverage."""
        trace = load_fixture("decode_saturated.trace.jsonl")
        assert sum(r["preempts"] for r in trace.ticks) > 0
        assert min(r["kv_free"] for r in trace.ticks) <= \
            trace.header["throttle"]["kv_threshold"]
        assert any(r["prefill_budget"] == 0 and r["wp"] > 0
                   for r in trace.ticks), "UT gate never engaged"


# ---------------------------------------------------------------------------
# Calibration (ISSUE acceptance: <= 5% mean relative error)
# ---------------------------------------------------------------------------

class TestFitFromTrace:
    @pytest.mark.parametrize("name", FIXTURES)
    def test_fit_recovers_latencies_within_5pct(self, name):
        trace = load_fixture(name)
        base = cost_model_for(get_config("qwen2.5-14b"), pp=trace.depth)
        # start the fit far from the truth: a third the compute efficiency,
        # inflated memory efficiency, 5x the fixed floor
        perturbed = dataclasses.replace(base, mfu=base.mfu / 3,
                                        hbm_eff=min(0.99, base.hbm_eff * 1.3),
                                        fixed_us=base.fixed_us * 5)
        fitted = CostModel.fit_from_trace(trace, perturbed)
        assert calibration_error(trace, fitted) < 0.05
        assert calibration_error(trace, fitted) < \
            calibration_error(trace, perturbed)

    def test_fit_on_prefill_heavy_recovers_both_regimes(self):
        trace = load_fixture("prefill_heavy.trace.jsonl")
        base = cost_model_for(get_config("qwen2.5-14b"), pp=trace.depth)
        perturbed = dataclasses.replace(base, mfu=0.2, hbm_eff=0.95)
        fitted = CostModel.fit_from_trace(trace, perturbed)
        # the fixture was generated by `base`; the fit must land back on it
        assert fitted.mfu == pytest.approx(base.mfu, rel=0.05)
        assert fitted.hbm_eff == pytest.approx(base.hbm_eff, rel=0.05)

    def test_tick_samples_shape(self):
        trace = load_fixture("prefill_heavy.trace.jsonl")
        samples = tick_samples(trace)
        assert 0 < len(samples) <= len(trace.ticks)
        for s in samples:
            assert s.prefill_tokens >= 0 and s.decode_tokens >= 0
            assert s.stage_time > 0


class TestAttnPageTerm:
    """Per-scanned-page attention billing (DESIGN.md §14): the CostModel
    mirror of the depth-bucketed engine.  Disabled (attn_page_bytes=0) it is
    bit-identical to the legacy per-token formula; enabled, the sim, the
    trace fit, and `calibration_error` all share one page estimator, so a
    trace minted under the page model fits back to itself."""

    def test_disabled_term_is_legacy_formula(self):
        base = cost_model_for(get_config("qwen2.5-14b"), pp=4)
        assert base.attn_page_bytes == 0.0
        # scanned_pages must be ignored when the term is off
        assert base.stage_time(64, 8, 400, 900, scanned_pages=10_000) == \
            base.stage_time(64, 8, 400, 900)

    def test_enabled_term_tracks_pages(self):
        cfg = get_config("qwen2.5-14b")
        paged = cost_model_for(cfg, pp=4, page_size=16)
        assert paged.attn_page_bytes == pytest.approx(
            16 * paged.kv_bytes_per_ctx_token)
        # more scanned pages => strictly more memory time (decode is
        # KV-bound at long context)
        lo = paged.stage_time(0, 8, 0, 8_000, scanned_pages=100)
        hi = paged.stage_time(0, 8, 0, 8_000, scanned_pages=100_000)
        assert hi > lo
        # the estimator backs the default: explicit == estimated
        est = paged.est_scanned_pages(0, 8, 0, 8_000)
        assert paged.stage_time(0, 8, 0, 8_000) == \
            paged.stage_time(0, 8, 0, 8_000, scanned_pages=est)

    def test_fit_recovers_page_model(self, tmp_path):
        """Mint a trace under the page-billing model, perturb the
        efficiencies, fit — the fit must land back on the truth (fit and
        generation share est_scanned_pages, so the term is identified)."""
        spec = WorkloadSpec("mix", mean_input=120.0, mean_output=24.0,
                            sigma=0.6, max_input=256, max_output=48)
        path = str(tmp_path / "paged.trace.jsonl")
        sim = record_sim_trace(path, sample_requests(spec, 24, 150.0, seed=3),
                               pages=512, attn_page_billing=True)
        base = sim.backend.cost
        assert base.attn_page_bytes > 0
        trace = Trace.load(path)
        perturbed = dataclasses.replace(base, mfu=base.mfu / 3,
                                        hbm_eff=min(0.99, base.hbm_eff * 1.3),
                                        fixed_us=base.fixed_us * 5)
        fitted = CostModel.fit_from_trace(trace, perturbed)
        assert fitted.attn_page_bytes == base.attn_page_bytes
        assert calibration_error(trace, fitted) < 0.05
        assert calibration_error(trace, fitted) < \
            calibration_error(trace, perturbed)


# ---------------------------------------------------------------------------
# Per-tick host overhead (schema 1.3 `host_s`)
# ---------------------------------------------------------------------------

class TestHostOverhead:
    def test_sim_traces_record_host_s(self):
        """SimBackend models host work per non-bubble tick; the recorder
        writes it, and the golden fixtures therefore pin it."""
        from repro.runtime.trace import host_overhead_samples
        trace = load_fixture(FIXTURES[0])
        samples = host_overhead_samples(trace)
        assert len(samples) == sum(1 for r in trace.ticks if r["batch"])
        assert all(s > 0 for s in samples)
        # bubble ticks cost no host work in the sim model
        assert all(r.get("host_s") == 0.0 for r in trace.ticks
                   if r["batch"] is None and "host_s" in r)

    def test_fit_from_trace_recovers_runtime_model(self):
        """The sim's host_s is deterministic per non-bubble tick, so the
        calibration recovers `host_s_per_tick` exactly and splits it by the
        requested overlap fraction."""
        from repro.runtime.simulator import RuntimeModel
        trace = load_fixture(FIXTURES[0])
        truth = RuntimeModel.gllm().host_s_per_tick
        fitted = RuntimeModel.fit_from_trace(trace)
        assert fitted.host_s_per_tick == pytest.approx(truth)
        assert fitted.overhead_overlap == 0.0
        split = RuntimeModel.fit_from_trace(trace, overlap_fraction=0.75)
        assert split.host_s_per_tick == pytest.approx(truth)
        assert split.overhead_overlap == pytest.approx(0.75 * truth)
        with pytest.raises(ValueError, match="overlap_fraction"):
            RuntimeModel.fit_from_trace(trace, overlap_fraction=1.5)

    def test_fit_from_trace_rejects_legacy_traces(self):
        """A pre-1.3 trace (no host_s anywhere) cannot calibrate the host
        model — explicit error, not a silent zero."""
        from repro.runtime.simulator import RuntimeModel
        trace = load_fixture(FIXTURES[0])
        legacy = Trace(copy.deepcopy(trace.header),
                       copy.deepcopy(trace.records))
        for rec in legacy.records:
            rec.pop("host_s", None)
        with pytest.raises(ValueError, match="host_s"):
            RuntimeModel.fit_from_trace(legacy)

    def test_legacy_records_round_trip_without_host_s(self):
        """Stripping host_s yields exactly the pre-1.3 byte layout: the
        field is uniformly optional, never null-filled."""
        from repro.runtime.trace import (compact_records, dumps_record,
                                         expand_records)
        with open(fixture_path(FIXTURES[0])) as fh:
            records = [json.loads(line) for line in fh if line.strip()]
        for rec in records:
            rec.pop("host_s", None)
        out = [dumps_record(r) for r in expand_records(compact_records(records))]
        assert out == [dumps_record(r) for r in records]
        assert all('"host_s"' not in line for line in out)


# ---------------------------------------------------------------------------
# Tracing across the runtime: live engine and multi-replica cluster
# ---------------------------------------------------------------------------

class TestEngineTrace:
    def test_engine_records_then_replays_offline(self, tmp_path):
        """The live `JaxBackend` is traced by the same recorder, and the
        trace replays through the scheduler alone — no model, no jax —
        reproducing the engine's exact sampled tokens and decisions."""
        import dataclasses as dc

        import jax

        from repro.jax_compat import ensure_jax_compat
        ensure_jax_compat()          # jax imported after repro: shim now

        import jax.numpy as jnp
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.configs import make_reduced
        from repro.core import SamplingParams
        from repro.models import transformer as tfm
        from repro.models.serve import ServeDims
        from repro.runtime.engine import PipelineEngine

        cfg = make_reduced(get_config("qwen1.5-0.5b")).with_plan(
            pp=1, tp=1, ep_over_data=False)
        cfg = dc.replace(cfg, dtype="float32")
        mesh = jax.make_mesh((1, 1, 1), ("data", "stage", "tensor"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
        dims = ServeDims(Sp=1, C=16, Sd=8, pages=256, page=8, Bp=32, Bd=32,
                         slots=16)
        th = ThrottleConfig(num_iters_T=2, max_prefill_tokens=16,
                            min_prefill_tokens=4, pipeline_depth=1)
        path = str(tmp_path / "engine.trace.jsonl")
        with jax.set_mesh(mesh):
            params = tfm.init_params(cfg, jax.random.key(0),
                                     dtype=jnp.float32)
            params = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                params, tfm.param_pspecs(cfg),
                is_leaf=lambda x: isinstance(x, P))
            eng = PipelineEngine(cfg, dims, params, mesh, th,
                                 trace_path=path)
        rng = np.random.default_rng(0)
        reqs = [eng.add_request(list(rng.integers(0, cfg.vocab_size, n)),
                                SamplingParams(max_new_tokens=4))
                for n in (5, 9, 12)]
        eng.drain()
        eng.recorder.close()

        trace = Trace.load(path)
        assert len(trace.requests) == 3
        report = replay_trace(trace)        # strict: decisions must match
        assert report.outputs() == {r.request_id: list(r.output_token_ids)
                                    for r in reqs}
        # engine backends cannot attribute per-stage time: recorded as null
        assert all(r["stage_times"] is None for r in trace.ticks)
        # ...but they do measure per-tick host overhead (schema 1.3)
        assert all(r["host_s"] > 0 for r in trace.ticks)


class TestClusterTrace:
    def test_sim_cluster_records_replicas_and_routing(self, tmp_path):
        from repro.data.workload import SHAREGPT
        from repro.runtime.router import ReplicaRouter, SimCluster
        from repro.runtime.simulator import PipelineSimulator

        def make_sched(pages=4096, pp=3):
            th = ThrottleConfig(pipeline_depth=pp)
            kv = PagedKVManager(num_pages=pages, page_size=16)
            return PipelineScheduler(th, kv, max_model_len=pages * 16)

        cost = cost_model_for(get_config("qwen2.5-14b"), pp=3)
        sims = [PipelineSimulator(make_sched(), 3, cost) for _ in range(2)]
        router = ReplicaRouter(sims, policy="balanced")
        cluster = SimCluster(sims, router, trace_dir=str(tmp_path))
        arrivals = sample_requests(SHAREGPT, 30, 30.0, seed=3)
        finished = cluster.run(arrivals)
        assert len(finished) == 30

        per_replica = 0
        for i in range(2):
            trace = Trace.load(str(tmp_path / f"replica{i}.trace.jsonl"))
            report = replay_trace(trace)   # each replica trace is golden
            per_replica += len(report.finished)
        assert per_replica == 30
        route = Trace.load(str(tmp_path / "router.trace.jsonl"),
                           expect="gllm-route")
        decisions = [r for r in route.records if r["kind"] == "route"]
        assert len(decisions) == 30
        assert [d["replica"] for d in decisions].count(0) == \
            router.routed_counts[0]
        assert all(len(d["scores"]) == 2 for d in decisions)


# ---------------------------------------------------------------------------
# Recorder invariants (property test; import-guarded like test_throttle)
# ---------------------------------------------------------------------------

def _check_recorder_invariants(trace: Trace) -> None:
    batches = {}
    prev_tick, prev_now, prev_rd = -1, None, None
    promotions_prev = 0      # decode promotions retired at the previous tick
    for rec in trace.records:
        if rec["kind"] != "tick":
            continue
        assert rec["tick"] == prev_tick + 1, "tick indices must be dense"
        prev_tick = rec["tick"]
        if prev_now is not None:
            assert rec["now"] >= prev_now, "time must not run backwards"
        prev_now = rec["now"]
        assert 0.0 <= rec["kv_free"] <= 1.0
        assert rec["wp"] >= 0 and rec["rd"] >= 0
        assert rec["preempts"] >= 0
        batch = rec["batch"]
        if batch is not None:
            batches[batch["id"]] = batch
            for _, start, length, _ in batch["prefill"]:
                assert start >= 0 and length > 0
            for _, pos in batch["decode"]:
                assert pos >= 0
            assert len(batch["decode"]) <= rec["rd"], \
                "cannot decode more seqs than are resident"
            assert rec["stage_times"] is not None
            assert all(t > 0 for t in rec["stage_times"])
            assert len(rec["stage_times"]) == trace.depth
        # decode population is monotone between admissions: it only grows
        # by prefills promoted at the previous tick's retirement
        if prev_rd is not None:
            assert rec["rd"] <= prev_rd + promotions_prev, \
                f"decode population jumped at tick {rec['tick']}"
        prev_rd = rec["rd"]
        exit_rec = rec["exit"]
        promotions_prev = 0
        if exit_rec is not None:
            exited = batches.get(exit_rec["id"])
            assert exited is not None, "exiting batch never entered"
            n_produce = sum(s[3] for s in exited["prefill"]) \
                + len(exited["decode"])
            assert len(exit_rec["tokens"]) == n_produce
            promotions_prev = sum(s[3] for s in exited["prefill"])


@pytest.mark.parametrize("name", FIXTURES)
def test_fixture_traces_satisfy_recorder_invariants(name):
    # non-hypothesis spot-check (requirements-dev.txt installs hypothesis)
    _check_recorder_invariants(load_fixture(name))


if HAS_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(3, 16),
        rate=st.floats(5.0, 60.0),
        mean_in=st.floats(8.0, 200.0),
        mean_out=st.floats(1.0, 48.0),
        pages=st.integers(48, 512),
    )
    def test_recorder_invariants_hold_on_random_workloads(
            seed, n, rate, mean_in, mean_out, pages):
        spec = WorkloadSpec("prop", mean_input=mean_in, mean_output=mean_out,
                            sigma=0.8, max_input=256, max_output=64)
        sink = io.StringIO()
        sim = record_sim_trace(sink, sample_requests(spec, n, rate,
                                                     seed=seed), pages=pages)
        trace = Trace.loads(sink.getvalue())
        assert len(trace.requests) == n
        _check_recorder_invariants(trace)
        # and every random trace must replay strictly
        report = replay_trace(trace)
        assert len(report.finished) == len(sim.metrics.finished)
