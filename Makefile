# Developer / CI entry points.
#
#   make dev-deps   install test-only dependencies (pytest, hypothesis)
#   make test       tier-1 suite (works without dev-deps; property tests
#                   skip themselves when hypothesis is missing)
#   make ci         dev-deps + tier-1
#   make bench      fast benchmark sweep (CSV rows on stdout)

PY ?= python
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)
export PYTHONPATH

.PHONY: dev-deps test ci bench

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt

test:
	$(PY) -m pytest -x -q

ci: dev-deps test

bench:
	$(PY) -m benchmarks.run --fast
