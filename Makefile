# Developer / CI entry points.
#
#   make dev-deps     install test-only dependencies (pytest, hypothesis)
#   make test         tier-1 suite (works without dev-deps; property tests
#                     skip themselves when hypothesis is missing)
#   make trace-check  strict-replay the checked-in golden traces (jax-free):
#                     any batching change in scheduler/throttle fails here
#   make rebalance-check  sim-only control-plane smoke: steal+migrate must
#                     beat admission-only p95 TTFT on the straggler cluster
#   make prefix-check  sim-only prefix-caching smoke: cache-aware routing
#                     must beat a cache-blind router on prefill tokens
#                     avoided without losing mean TTFT
#   make disagg-check  sim-only disaggregation smoke: the best prefill:decode
#                     split must not lose to the throttled hybrid on
#                     interactive goodput or p95 TBT, with handoffs flowing
#   make autoscale-check  sim-only elasticity smoke: the autoscaled fleet
#                     must hold the static fleet's interactive SLO
#                     attainment at <= 75% of its replica-seconds, with
#                     scale-ups and retirements both demonstrably firing
#                     (the fleet-scale soak itself runs in tier-1;
#                     REPRO_SOAK_REPLICAS caps its CI fleet, default 16)
#   make examples-check  run the examples end-to-end against the public
#                     serving API (reduced engine on CPU + the HTTP demo)
#   make docs-check   run every fenced python block in README.md + docs/
#                     (sim backend, jax-free) and verify relative links
#   make bench-smoke  seconds-scale run of the engine perf harness (all
#                     four dispatch/shape variants, bit-identity asserted)
#                     plus schema validation of the checked-in
#                     BENCH_engine.json and BENCH_autoscale.json
#   make ci           dev-deps + tier-1 + golden traces + rebalance smoke
#                     + prefix smoke + disagg smoke + autoscale smoke
#                     + examples + docs + bench smoke
#   make bench        fast benchmark sweep (CSV rows on stdout)

PY ?= python
PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)
export PYTHONPATH

TRACE_FIXTURES := tests/fixtures/traces/prefill_heavy.trace.jsonl \
                  tests/fixtures/traces/decode_saturated.trace.jsonl

.PHONY: dev-deps test trace-check rebalance-check prefix-check disagg-check \
        autoscale-check examples-check docs-check bench-smoke ci bench

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt

test:
	$(PY) -m pytest -x -q

trace-check:
	$(PY) -m repro.runtime.trace check $(TRACE_FIXTURES)

rebalance-check:
	$(PY) -m benchmarks.fig_rebalance --check

prefix-check:
	$(PY) -m benchmarks.fig_prefix_cache --check

disagg-check:
	$(PY) -m benchmarks.fig_disagg --check

autoscale-check:
	$(PY) -m benchmarks.fig_autoscale --check

examples-check:
	$(PY) examples/quickstart.py
	$(PY) examples/serve_offline.py 8
	$(PY) examples/serve_online.py
	$(PY) examples/serve_http.py

docs-check:
	$(PY) tools/docs_check.py

bench-smoke:
	$(PY) benchmarks/bench_engine.py --smoke
	$(PY) benchmarks/bench_engine.py --validate BENCH_engine.json
	$(PY) -m benchmarks.fig_autoscale --validate BENCH_autoscale.json

ci: dev-deps test trace-check rebalance-check prefix-check disagg-check \
    autoscale-check examples-check docs-check bench-smoke

bench:
	$(PY) -m benchmarks.run --fast
